"""Run any registered workload scenario through the virtual testbed.

One entrypoint for the whole scenario *and* policy registry: pick a
scenario, a policy and a load level; optionally also run the vmapped
Monte-Carlo fleet for replicated statistics.

    PYTHONPATH=src python examples/run_scenario.py --list
    PYTHONPATH=src python examples/run_scenario.py --scenario flash-crowd
    PYTHONPATH=src python examples/run_scenario.py --scenario outage --policy local_all
    PYTHONPATH=src python examples/run_scenario.py --scenario diurnal --policy random --fleet 32

Streaming scenarios (sustained-overload, diurnal-week) generate arrivals
frame-by-frame with bounded memory — pair them with long horizons; and
``--congestion`` enables load-dependent service times (over-committed
servers slow down, the regime where Happy-* collapse):

    PYTHONPATH=src python examples/run_scenario.py --scenario sustained-overload \
        --policy happy_computation --congestion --horizon-s 30
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import os

from repro.core import (
    CongestionConfig,
    EngineOptions,
    SimConfig,
    demo_cluster_spec,
    get_policy,
    get_scenario,
    gus_schedule_np,
    list_policies,
    list_scenarios,
    simulate,
    simulate_fleet,
)
from repro.obs import (
    AsyncJsonlWriter,
    profile_trace,
    recording,
    validate_chrome_trace,
)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="paper-default")
    ap.add_argument("--policy", default="gus",
                    help="registered policy name, or 'gus-np' for the NumPy oracle")
    ap.add_argument("--rate", type=float, default=2.0, help="arrivals/s per edge")
    ap.add_argument("--horizon-s", type=float, default=60.0)
    ap.add_argument("--deadline-ms", type=float, default=6000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=0, metavar="R",
                    help="also run R vmapped Monte-Carlo replications")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="shard the fleet's replication axis over N local "
                         "devices (default: all; asking for more than "
                         "jax.local_device_count() is an error, results are "
                         "bit-identical either way)")
    ap.add_argument("--window", type=int, default=None, metavar="W",
                    help="run the fleet scan W frames at a time "
                         "(bounded memory on long horizons)")
    ap.add_argument("--prefetch", type=int, default=None, metavar="D",
                    help="fleet host-pipeline depth: build window k+1's "
                         "arrivals+grid in a producer thread while window k "
                         "computes (bit-identical; 0 = serial build, "
                         "default 1)")
    ap.add_argument("--rng-mode", choices=["paper-default", "vectorized"],
                    default=None,
                    help="arrival generator: 'paper-default' keeps the "
                         "frozen per-request draw order (bit-compatible "
                         "traces), 'vectorized' batches the draws in numpy "
                         "(~10x faster generation, same distribution, "
                         "different seed-deterministic traces)")
    ap.add_argument("--backend", choices=["xla", "pallas"], default=None,
                    help="GUS scheduler implementation: 'xla' jitted loop "
                         "(default) or 'pallas' fused kernel (interpret mode "
                         "off-TPU; bit-identical assignments either way). "
                         "Applies to the default/'gus' policy only")
    ap.add_argument("--scheduler", choices=["dense", "hierarchical"],
                    default=None,
                    help="scheduling granularity: 'dense' (default) ranks "
                         "every request individually; 'hierarchical' buckets "
                         "requests into QoS class aggregates first and "
                         "schedules the aggregates (the 10^5-users-per-frame "
                         "path; gus-family policies only)")
    ap.add_argument("--congestion", action="store_true",
                    help="enable load-dependent service times (queueing model)")
    ap.add_argument("--metrics", action="store_true",
                    help="collect the per-frame metric stream (utilization, "
                         "backlog, QoS-class satisfaction, assignment tiers) "
                         "and write it as JSONL under results/telemetry/")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="override the metric stream's JSONL path "
                         "(default results/telemetry/<scenario>-<policy>"
                         ".metrics.jsonl)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record host spans for the whole run and save a "
                         "Chrome trace-event JSON (open in chrome://tracing "
                         "or Perfetto)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace of the run "
                         "into DIR (TensorBoard/Perfetto-loadable)")
    stream = ap.add_mutually_exclusive_group()
    stream.add_argument("--streaming", dest="streaming", action="store_true",
                        default=None,
                        help="force the bounded-memory arrival stream")
    stream.add_argument("--materialized", dest="streaming", action="store_false",
                        help="force the materialized arrival trace")
    ap.add_argument("--list", action="store_true",
                    help="list scenarios and policies, then exit")
    args = ap.parse_args(argv)

    if not args.fleet and (
        args.devices is not None or args.window is not None
        or args.prefetch is not None
    ):
        ap.error("--devices/--window/--prefetch configure the Monte-Carlo "
                 "fleet; add --fleet R")

    if args.list:
        print("scenarios:")
        for name in list_scenarios():
            print(f"  {name:15s} {get_scenario(name).description}")
        print("policies:")
        for name in list_policies():
            print(f"  {name:20s} {get_policy(name).description}")
        return

    spec = demo_cluster_spec()
    cfg = SimConfig(
        horizon_ms=args.horizon_s * 1000.0,
        arrival_rate_per_s=args.rate,
        delay_req_ms=args.deadline_ms,
        acc_req_mean=50.0,
        acc_req_std=10.0,
        congestion=CongestionConfig(enabled=args.congestion),
    )
    try:
        scn = get_scenario(args.scenario)
    except KeyError as e:
        raise SystemExit(e.args[0])
    # `gus-np` is the NumPy parity oracle, not a registered policy (it is the
    # thing the registered `gus` is tested against)
    sim_kw = (
        {"scheduler": gus_schedule_np} if args.policy == "gus-np"
        else {"policy": args.policy}
    )
    if args.policy == "gus-np":
        if args.backend is not None:
            raise SystemExit("--backend selects the jitted GUS implementation; "
                             "gus-np is the host-side NumPy oracle")
        if args.scheduler == "hierarchical":
            raise SystemExit("--scheduler hierarchical needs a registered "
                             "gus-family policy (not gus-np)")
    # every engine axis travels as one EngineOptions value; the per-call
    # keywords (streaming=, rng_mode=, ...) are deprecated aliases
    sim_opts = EngineOptions(
        streaming=args.streaming,
        rng_mode=args.rng_mode,
        backend=args.backend,
        scheduler=args.scheduler,
        metrics=args.metrics,
    )
    mode = []
    if args.congestion:
        mode.append("congestion")
    if args.backend == "pallas":
        mode.append("pallas-backend")
    if args.scheduler == "hierarchical":
        mode.append("hier-scheduler")
    if args.streaming or (args.streaming is None and scn.streaming):
        mode.append("streaming")
    if args.rng_mode == "vectorized" or (args.rng_mode is None and scn.rng_mode == "vectorized"):
        mode.append("vectorized-rng")
    tag = f" [{', '.join(mode)}]" if mode else ""
    print(f"=== scenario {scn.name!r} / policy {args.policy!r}{tag} ===")
    if args.metrics and args.policy == "gus-np":
        raise SystemExit("--metrics needs a registered policy (not gus-np)")

    fr = None
    rec_ctx = recording() if args.trace else contextlib.nullcontext()
    with profile_trace(args.profile), rec_ctx as rec:
        try:
            r = simulate(spec, cfg, scenario=scn, seed=args.seed,
                         options=sim_opts, **sim_kw)
        except (KeyError, ValueError) as e:  # unknown policy / ILP too big
            raise SystemExit(str(e.args[0]))
        for k, v in r.as_dict().items():
            print(f"  {k:20s} {float(v):10.3f}")
        if args.metrics:
            # export while the recorder is live: the writer thread's io
            # spans land in the trace alongside the simulation's
            out = args.metrics_out or os.path.join(
                "results", "telemetry",
                f"{scn.name}-{args.policy}.metrics.jsonl",
            )
            with AsyncJsonlWriter(out) as w:
                n_rows = r.metrics.to_jsonl(None, writer=w)
            print(f"=== metrics: {n_rows} rows -> {out} ===")
            for k, v in r.metrics.aggregate().items():
                print(f"  {k:20s} {v}")

        if args.fleet:
            if args.policy == "gus-np":
                raise SystemExit(
                    "gus-np is host-only; the fleet needs a registered policy"
                )
            try:
                # a --devices request the host cannot honor raises a clear
                # ValueError (never a silent single-device fallback)
                fleet_opts = dataclasses.replace(
                    sim_opts, devices=args.devices, window=args.window,
                    **({"prefetch": args.prefetch}
                       if args.prefetch is not None else {}),
                )
                fr = simulate_fleet(spec, cfg, scenario=scn, n_rep=args.fleet,
                                    seed=args.seed, options=fleet_opts,
                                    **sim_kw)
            except ValueError as e:  # bad --devices, ILP uncapped frame, ...
                raise SystemExit(str(e.args[0]))
            print(f"=== fleet: {args.fleet} replications on "
                  f"{fr.n_devices} device(s) ===")
            for k, v in fr.as_dict().items():
                print(f"  {k:20s} {float(v):10.3f}")
            if args.metrics:
                out = os.path.join(
                    "results", "telemetry",
                    f"{scn.name}-{args.policy}.fleet.metrics.jsonl",
                ) if args.metrics_out is None else (
                    args.metrics_out + ".fleet"
                )
                with AsyncJsonlWriter(out) as w:
                    n_rows = fr.metrics.to_jsonl(None, writer=w)
                print(f"=== fleet metrics: {n_rows} rows -> {out} ===")

    if args.trace:
        rec.save(args.trace)
        with open(args.trace) as f:
            errs = validate_chrome_trace(json.load(f))
        cats = sorted(rec.categories())
        print(f"=== trace: {len(rec)} events, categories {cats}, "
              f"{len(rec.thread_ids())} thread(s) -> {args.trace} "
              f"({'valid' if not errs else errs}) ===")
    return r, fr


if __name__ == "__main__":
    main()
