"""Run any registered workload scenario through the virtual testbed.

One entrypoint for the whole scenario registry: pick a scenario, a scheduler
and a load level; optionally also run the vmapped Monte-Carlo fleet for
replicated statistics.

    PYTHONPATH=src python examples/run_scenario.py --list
    PYTHONPATH=src python examples/run_scenario.py --scenario flash-crowd
    PYTHONPATH=src python examples/run_scenario.py --scenario outage --fleet 32
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.core import (
    SimConfig,
    demo_cluster_spec,
    get_scenario,
    gus_schedule_np,
    list_scenarios,
    local_all,
    offload_all,
    simulate,
    simulate_fleet,
)


def make_scheduler(name, spec):
    if name == "gus":
        return None  # simulate()'s default: the jitted gus_schedule hot path
    if name == "gus-np":
        return gus_schedule_np
    if name == "local_all":
        return local_all
    if name == "offload_all":
        cloud = jnp.arange(spec.n_servers) >= spec.n_edge
        return lambda inst: offload_all(inst, cloud)
    raise SystemExit(f"unknown scheduler {name!r}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="paper-default")
    ap.add_argument("--scheduler", default="gus",
                    choices=["gus", "gus-np", "local_all", "offload_all"])
    ap.add_argument("--rate", type=float, default=2.0, help="arrivals/s per edge")
    ap.add_argument("--horizon-s", type=float, default=60.0)
    ap.add_argument("--deadline-ms", type=float, default=6000.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=0, metavar="R",
                    help="also run R vmapped Monte-Carlo replications")
    ap.add_argument("--list", action="store_true", help="list scenarios and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in list_scenarios():
            print(f"{name:15s} {get_scenario(name).description}")
        return

    spec = demo_cluster_spec()
    cfg = SimConfig(
        horizon_ms=args.horizon_s * 1000.0,
        arrival_rate_per_s=args.rate,
        delay_req_ms=args.deadline_ms,
        acc_req_mean=50.0,
        acc_req_std=10.0,
    )
    try:
        scn = get_scenario(args.scenario)
    except KeyError as e:
        raise SystemExit(e.args[0])
    print(f"=== scenario {scn.name!r}: {scn.description} ===")
    r = simulate(spec, cfg, make_scheduler(args.scheduler, spec),
                 scenario=scn, seed=args.seed)
    for k, v in r.as_dict().items():
        print(f"  {k:20s} {float(v):10.3f}")

    if args.fleet:
        fr = simulate_fleet(spec, cfg, scenario=scn, n_rep=args.fleet, seed=args.seed)
        print(f"=== fleet: {args.fleet} replications, one device program ===")
        for k, v in fr.as_dict().items():
            print(f"  {k:20s} {float(v):10.3f}")


if __name__ == "__main__":
    main()
