"""Fleet-scale scheduling: GUS over the 10 assigned architectures.

Builds a 10-service zoo (one service per assigned arch, each with a 4-variant
accuracy/cost ladder), derives T^proc from the analytic roofline profiles on
heterogeneous TPU tiers, and runs the time-slotted simulator under rising
load — the paper's scenario at production scale, where the "models" are
pixtral/qwen2/arctic/... rather than SqueezeNet.

Run:  PYTHONPATH=src python examples/schedule_cluster.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import SimConfig, gus_schedule_np, local_all, offload_all, simulate
from repro.serving import ModelZoo, ServiceSpec, build_cluster_spec, variant_ladder


def main():
    services = []
    for arch in ARCH_IDS:
        base = get_config(arch)
        services.append(ServiceSpec(arch, variant_ladder(base, 4)))
    zoo = ModelZoo(services)

    spec = build_cluster_spec(
        zoo,
        edge_classes=["edge-1", "edge-4", "edge-4", "edge-8"],
        cloud_classes=["cloud-256"],
        edge_variants=3,
        edge_service_frac=0.7,
        prompt_tokens=512,
        gen_tokens=64,
        seed=0,
    )
    print("T^proc (ms) ranges per tier:")
    for j, name in enumerate(["edge-1", "edge-4", "edge-4", "edge-8", "cloud-256"]):
        p = spec.proc_ms[j][spec.placed[j]]
        if p.size:
            print(f"  {name:10s} {p.min():9.1f} .. {p.max():9.1f}")

    # capacities: chip-ms per 3s frame per tier
    spec.gamma_frame = np.array([3000.0, 12000.0, 12000.0, 24000.0, 300000.0], np.float32)
    spec.eta_frame = np.array([400.0, 600.0, 600.0, 800.0, 8000.0], np.float32)

    print("\nload  policy        satisfied%  local%  cloud%  edge-off%  dropped%")
    for rate in (2.0, 6.0, 12.0):
        cfg = SimConfig(
            horizon_ms=60_000.0,
            arrival_rate_per_s=rate,
            delay_req_ms=4000.0,
            acc_req_mean=80.0,
            acc_req_std=6.0,
            queue_cap=4,
        )
        for name, sched in [
            ("GUS", gus_schedule_np),
            ("local-all", lambda i: local_all(i)),
            ("offload-all", lambda i: offload_all(i, jnp.arange(5) >= 4)),
        ]:
            d = simulate(spec, cfg, sched, seed=0).as_dict()
            print(
                f"{rate:4.0f}  {name:13s} {d['satisfied_pct']:9.1f} "
                f"{d['local_pct']:7.1f} {d['cloud_pct']:7.1f} "
                f"{d['edge_offload_pct']:9.1f} {d['dropped_pct']:8.1f}"
            )
    print("\nGUS composes local/cloud/edge-offload per tier exactly as the paper's Fig. 1(e)-(h).")


if __name__ == "__main__":
    main()
