"""Quickstart: the paper's pipeline end to end in ~30 seconds on CPU.

1. Build a model zoo (the paper's '|L| DL variants per service').
2. Derive the scheduler's T^proc/accuracy tables from the models themselves.
3. Generate a burst of user requests with QoS (A_i, C_i) demands.
4. Schedule with GUS and with every baseline; compare satisfaction.
5. Solve a small instance exactly and show GUS is near-optimal.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    GeneratorConfig,
    generate_instance,
    gus_schedule,
    local_all,
    mean_us,
    offload_all,
    random_assignment,
    satisfied_mask,
    solve_bnb,
)
from repro.serving import variant_ladder, request_latency_ms, HW_CLASSES, accuracy_proxy


def main():
    # --- 1-2: zoo + profiles -------------------------------------------------
    print("=== model zoo (variants of yi-9b as one 'service') ===")
    ladder = variant_ladder(get_config("yi-9b"), 4)
    for v in ladder:
        acc = accuracy_proxy(v.n_params())
        lat_edge = request_latency_ms(v, HW_CLASSES["edge-1"])
        lat_cloud = request_latency_ms(v, HW_CLASSES["cloud-256"])
        print(
            f"  {v.arch_id:12s} {v.n_params()/1e9:5.2f}B acc~{acc:4.1f}% "
            f"T^proc edge-1={lat_edge:8.1f}ms cloud-256={lat_cloud:6.1f}ms"
        )

    # --- 3: a burst of requests (paper Sec. IV numerical setup) ---------------
    inst = generate_instance(seed=0, cfg=GeneratorConfig())
    print(f"\n=== {inst.n_requests} requests, {inst.n_servers} servers "
          f"(9 edge + 1 cloud), {inst.n_variants} variants/service ===")

    # --- 4: schedule ----------------------------------------------------------
    cloud = jnp.arange(inst.n_servers) >= 9
    policies = {
        "GUS (paper)": gus_schedule(inst),
        "random": random_assignment(inst, jax.random.PRNGKey(0)),
        "local-all": local_all(inst),
        "offload-all": offload_all(inst, cloud),
    }
    for name, a in policies.items():
        sat = int(satisfied_mask(inst, a.j, a.l).sum())
        us = float(mean_us(inst, a.j, a.l))
        off = int((a.offloaded(inst)).sum())
        print(f"  {name:12s} satisfied {sat:3d}/100  mean-US {us:.3f}  offloaded {off}")

    # --- 5: optimality gap -----------------------------------------------------
    tiny = generate_instance(
        1, GeneratorConfig(n_requests=8, n_edge=3, n_cloud=1, n_services=4, n_variants=3)
    )
    _, opt = solve_bnb(tiny)
    a = gus_schedule(tiny)
    g = float(mean_us(tiny, a.j, a.l))
    print(f"\n=== exact ILP check (8 requests): OPT={opt:.4f} GUS={g:.4f} "
          f"ratio={g/max(opt,1e-9):.3f} ===")


if __name__ == "__main__":
    main()
