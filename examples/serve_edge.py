"""End-to-end driver (deliverable (b)): the paper's testbed, for real.

Trains the paper-analog zoo (SqueezeNet/GoogleNet-style tiny LMs) on CPU,
MEASURES each variant's latency and next-token accuracy with the serving
engine, feeds those measurements into the GUS scheduler — including the
paper's EMA bandwidth-estimate update rule — and serves a stream of batched
requests, reporting satisfied-%.

This is the full loop the paper implements in C++ on Raspberry Pis, here as
one JAX program:   train -> profile -> schedule -> serve -> measure.

Run:  PYTHONPATH=src python examples/serve_edge.py [--steps 120]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.paper_zoo import GOOGLE_LM, MID_LM, SQUEEZE_LM
from repro.training.data import SyntheticLM
from repro.core import ClusterSpec, SimConfig, gus_schedule_np, local_all, offload_all, simulate
from repro.models import Model
from repro.serving import ServingEngine
from repro.training import AdamWConfig, init_state, make_batch, make_train_step


# one shared learnable task (peaky Markov chain).  NOTE: at CPU scale (a few
# hundred steps) all three sizes converge to similar accuracy — the paper's
# accuracy axis comes from mature pre-trained models (SqueezeNet vs GoogleNet);
# here the measured LATENCY ladder (size-proportional) drives the trade-off,
# and examples/schedule_cluster.py demonstrates the accuracy axis with the
# scaling-law proxy.  Accuracies below are measured, not asserted.
VOCAB = 128
SOURCE = SyntheticLM(VOCAB, seed=7, alpha=0.003)

# size ladder shrunk so the example runs in ~3 min on CPU
SIZES = {
    "squeeze-lm": dict(num_layers=2, d_model=96, num_heads=4, num_kv_heads=2, d_ff=256),
    "mid-lm": dict(num_layers=3, d_model=160, num_heads=4, num_kv_heads=2, d_ff=512),
    "google-lm": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2, d_ff=768),
}


def train_variant(cfg, steps, seed=0):
    cfg = dataclasses.replace(cfg, vocab_size=VOCAB, **SIZES[cfg.arch_id])
    model = Model(cfg)
    opt = AdamWConfig(lr=1e-2, total_steps=steps, warmup_steps=max(steps // 10, 1))
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    state = init_state(model, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    first = last = None
    for i in range(steps):
        state, m = step(state, make_batch(cfg, 8, 64, rng, SOURCE))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    return model, state.params, first, last


def main(steps=200):
    # --- train the zoo (SqueezeNet/GoogleNet analogs) -------------------------
    variants = [SQUEEZE_LM, MID_LM, GOOGLE_LM]
    engines, acc, proc_edge, proc_cloud = [], [], [], []
    rng = np.random.default_rng(0)
    for cfg in variants:
        t0 = time.time()
        model, params, l0, l1 = train_variant(cfg, steps)
        eng = ServingEngine(model, params)
        eval_batch = make_batch(model.cfg, 8, 64, rng, SOURCE)
        a = eng.eval_next_token_accuracy(eval_batch) * 100
        r = eng.generate(make_batch(model.cfg, 1, 32, rng, SOURCE), max_new_tokens=8)
        engines.append(eng)
        acc.append(a)
        # measured latency; the 'cloud' runs the same hardware here, so model
        # the paper's RPi4-vs-desktop gap with its measured 1300:300 ratio
        proc_edge.append(r.total_ms)
        proc_cloud.append(r.total_ms * 300.0 / 1300.0)
        print(
            f"{cfg.arch_id:11s} trained {steps} steps ({time.time()-t0:.0f}s): "
            f"loss {l0:.2f}->{l1:.2f}, next-token acc {a:.1f}%, "
            f"measured latency {r.total_ms:.0f}ms",
            flush=True,
        )
    assert max(acc) > 30.0, "zoo should learn the task well beyond chance"
    if acc[-1] <= acc[0]:
        print(f"note: accuracy ladder within training noise at CPU scale "
              f"({acc[0]:.1f}% vs {acc[-1]:.1f}%) — see header comment")

    # --- build the cluster from MEASURED profiles ------------------------------
    K, L, M = 3, len(variants), 3  # 2 edges + 1 cloud, 3 services sharing the zoo
    proc = np.zeros((M, K, L), np.float32)
    placed = np.zeros((M, K, L), bool)
    for j in range(2):  # edges hold the two cheap variants
        proc[j, :, :] = np.array(proc_edge)[None, :]
        placed[j, :, :2] = True
    proc[2, :, :] = np.array(proc_cloud)[None, :]
    placed[2, :, :] = True
    acc_kl = np.broadcast_to(np.array(acc, np.float32)[None, :], (K, L)).copy()

    spec = ClusterSpec(
        n_edge=2,
        n_cloud=1,
        gamma_frame=np.array([3 * max(proc_edge), 3 * max(proc_edge), 10 * max(proc_cloud)], np.float32),
        eta_frame=np.array([350.0, 350.0, 3500.0], np.float32),
        proc_ms=proc,
        placed=placed,
        acc=acc_kl,
    )

    # --- serve a request stream through GUS (EMA bandwidth inside) ------------
    simcfg = SimConfig(
        horizon_ms=90_000.0,
        arrival_rate_per_s=4.0,
        delay_req_ms=4.0 * max(proc_edge),
        acc_req_mean=max(min(acc) - 1.0, 1.0),  # all variants accuracy-feasible;
        # the latency/capacity axes drive scheduling (see header comment)
        frame_ms=3000.0,
        queue_cap=4,
    )
    print("\npolicy        satisfied%  local%  cloud%  edge-off%  dropped%  [bw estimates]")
    import jax.numpy as jnp

    for name, sched in [
        ("GUS", gus_schedule_np),
        ("local-all", lambda i: local_all(i)),
        ("offload-all", lambda i: offload_all(i, jnp.arange(3) >= 2)),
    ]:
        r = simulate(spec, simcfg, sched, seed=1)
        d = r.as_dict()
        bw = ", ".join(f"{b:.0f}" for b in r.bandwidth_estimates[:4])
        print(
            f"{name:13s} {d['satisfied_pct']:9.1f} {d['local_pct']:7.1f} "
            f"{d['cloud_pct']:7.1f} {d['edge_offload_pct']:9.1f} "
            f"{d['dropped_pct']:8.1f}  [{bw}, ...]"
        )
        if name == "GUS":
            gus_sat = d["satisfied_pct"]
    assert gus_sat >= 50.0, "GUS should satisfy most users in this regime"
    print("\nend-to-end: trained zoo -> measured profiles -> GUS serving OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    main(args.steps)
